"""Fault-tolerant elastic fixpoint (ShardedExecutor.run_resilient).

Contract under test: resilience changes WHEN/WHERE work happens (replica
persistence, shard rebuilds, snapshot migration, speculation) but never
WHAT is computed — a resilient run with any injected fault schedule must
reach a final state bit-identical (XLA CPU) to the failure-free
``ShardedExecutor.run``, with the ladder and route-strategy dispatch
semantics intact.
"""
import os
import tempfile

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

import jax.numpy as jnp

from repro.algorithms import emission, pagerank, sssp
from repro.core.delta import PAD_KEY
from repro.core.engine import DeltaAlgorithm, ShardedExecutor
from repro.core.partition import PartitionSnapshot, unshard_dense_state
from repro.data.graphs import make_powerlaw_graph, shard_csr
from repro.runtime import (FaultEvent, FaultPlan, FaultSchedule,
                           ReplicaChain, SpeculationPolicy,
                           apply_route_buffer, migrate_route_buffers)

SRC = os.path.join(os.path.dirname(__file__), "..", "src")
N, S = 512, 4


@pytest.fixture(scope="module")
def graph():
    indptr, indices = make_powerlaw_graph(N, avg_degree=8.0, seed=0)
    snap = PartitionSnapshot(n_keys=N, num_shards=S)
    return indptr, indices, snap, shard_csr(indptr, indices, S)


def make_executor(snap, **kw):
    kw.setdefault("ladder_tiers", 4)
    return ShardedExecutor(snapshot=snap, seg_capacity=8192,
                          edge_capacity=8192,
                          src_capacity=snap.block_size, **kw)


def states_equal(a, b) -> bool:
    return bool(jnp.all(jnp.stack(
        [jnp.all(x == y) for x, y in zip(a, b)])))


def make_max_algorithm(snapshot: PartitionSnapshot, src_capacity: int,
                       edge_capacity: int) -> DeltaAlgorithm:
    """Max-label propagation — the max-combiner member of the Δᵢ family
    (mirror of connected components with the order flipped)."""
    block = snapshot.block_size
    NEG = jnp.float32(-jnp.inf)

    def active_fn(state, graph):
        label, sent = state
        active = label > sent
        est = jnp.sum(jnp.where(active, graph.out_degree, 0))
        return active, est

    def make_sparse_emit(src_cap, edge_cap):
        def sparse_emit(state, graph, active, stratum, shard_id):
            label, sent = state
            payload = jnp.where(active, label, NEG)
            out = emission.emit_over_edges(graph, active, payload,
                                           src_cap, edge_cap)
            new_sent = jnp.where(active, label, sent)
            return (label, new_sent), out
        return sparse_emit

    def dense_emit(state, graph, stratum, shard_id):
        label, sent = state
        dst, pay = emission.dense_push(graph, label)
        pay = jnp.where(dst >= 0, pay, NEG)
        n_padded = snapshot.padded_keys
        contrib = jnp.full((n_padded + 1,), NEG, pay.dtype).at[
            jnp.where(dst >= 0, dst, n_padded)].max(
            pay, mode="drop")[:n_padded]
        return (label, label), contrib[:, None]

    def apply_sparse(state, incoming, graph, stratum, shard_id):
        label, sent = state
        inc = emission.scatter_local(incoming, shard_id, block, "max")
        new_label = jnp.maximum(label, inc)
        return (new_label, sent), jnp.sum(
            (new_label > sent).astype(jnp.int32))

    def apply_dense(state, incoming, graph, stratum, shard_id):
        label, sent = state
        new_label = jnp.maximum(label, incoming[:, 0])
        return (new_label, sent), jnp.sum(
            (new_label > sent).astype(jnp.int32))

    return DeltaAlgorithm(
        active_fn=active_fn, sparse_emit=make_sparse_emit(src_capacity,
                                                          edge_capacity),
        dense_emit=dense_emit, apply_sparse=apply_sparse,
        apply_dense=apply_dense, combiner="max", payload_width=1,
        emit_factory=make_sparse_emit)


def max_initial_state(snapshot: PartitionSnapshot):
    S_, B = snapshot.num_shards, snapshot.block_size
    label = jnp.arange(S_ * B, dtype=jnp.float32).reshape(S_, B)
    sent = jnp.full((S_, B), -jnp.inf, jnp.float32)
    return (label, sent)


def setup_algo(name, snap, graph_sharded):
    """-> (algo, state0, live0) for "pr" | "sssp" | "maxprop"."""
    caps = dict(src_capacity=snap.block_size, edge_capacity=8192)
    if name == "pr":
        return (pagerank.make_algorithm(snap, **caps),
                pagerank.initial_state(snap), snap.padded_keys)
    if name == "sssp":
        return (sssp.make_algorithm(snap, **caps),
                sssp.initial_state(snap, 0), 1)
    return (make_max_algorithm(snap, **caps), max_initial_state(snap),
            snap.padded_keys)


# ---------------------------------------------------------------------------
# Replica chain: property tests of restore + migration.
# ---------------------------------------------------------------------------

class TestReplicaChain:
    BLOCK, W = 16, 2

    def _snap(self, shards=4):
        return PartitionSnapshot(n_keys=shards * self.BLOCK,
                                 num_shards=shards)

    def _evolve(self, rng, packed, strata, chain):
        for _ in range(strata):
            nchanged = int(rng.integers(0, packed.shape[1] + 1))
            for s in range(packed.shape[0]):
                rows = rng.choice(packed.shape[1], size=nchanged,
                                  replace=False)
                packed[s, rows] = rng.normal(
                    size=(nchanged, self.W)).astype(np.float32)
            chain.append(packed)
        return packed

    @settings(max_examples=10, deadline=None)
    @given(strata=st.integers(1, 5), shard=st.integers(0, 3),
           seed=st.integers(0, 1 << 16))
    def test_restore_equals_live_shard(self, strata, shard, seed):
        rng = np.random.default_rng(seed)
        snap = self._snap()
        with tempfile.TemporaryDirectory() as td:
            chain = ReplicaChain(td, snap, self.W)
            chain.open_epoch()
            packed = rng.normal(size=(4, self.BLOCK, self.W)).astype(
                np.float32)
            chain.baseline(packed)
            packed = self._evolve(rng, packed, strata, chain)
            chain.wipe(shard)                       # disk loss
            got = chain.restore_shard(shard)
            np.testing.assert_array_equal(got, packed[shard])

    @settings(max_examples=10, deadline=None)
    @given(strata=st.integers(1, 4), post=st.integers(1, 4),
           shard=st.integers(0, 3), seed=st.integers(0, 1 << 16))
    def test_repeated_failure_of_same_shard(self, strata, post, shard,
                                            seed):
        """Second disk loss of an already-recovered shard: its own dir
        holds only post-recovery entries, the replicas hold the older
        ones — restore must union both (paper §4.3 forward progress)."""
        rng = np.random.default_rng(seed)
        snap = self._snap()
        with tempfile.TemporaryDirectory() as td:
            chain = ReplicaChain(td, snap, self.W)
            chain.open_epoch()
            packed = rng.normal(size=(4, self.BLOCK, self.W)).astype(
                np.float32)
            chain.baseline(packed)
            packed = self._evolve(rng, packed, strata, chain)
            chain.wipe(shard)
            got = chain.restore_shard(shard)
            np.testing.assert_array_equal(got, packed[shard])
            packed = self._evolve(rng, packed, post, chain)
            chain.wipe(shard)                     # same shard dies again
            got = chain.restore_shard(shard)
            np.testing.assert_array_equal(got, packed[shard])

    @settings(max_examples=10, deadline=None)
    @given(strata=st.integers(1, 4), post=st.integers(0, 3),
           new_shards=st.sampled_from([2, 8]), shard=st.integers(0, 1),
           seed=st.integers(0, 1 << 16))
    def test_migrated_chain_restores_under_new_snapshot(
            self, strata, post, new_shards, shard, seed):
        """Rescale mid-chain: the in-flight buffers re-routed through
        combine_route must make every NEW shard restorable."""
        rng = np.random.default_rng(seed)
        snap = self._snap()
        new_snap = snap.resnapshot(new_shards)
        nb = new_snap.block_size
        with tempfile.TemporaryDirectory() as td:
            chain = ReplicaChain(td, snap, self.W)
            chain.open_epoch()
            init = rng.normal(size=(4, self.BLOCK, self.W)).astype(
                np.float32)
            packed = init.copy()
            chain.baseline(packed)
            packed = self._evolve(rng, packed, strata, chain)
            # remap is a pure reshape for the block scheme at fixed n_keys
            new_init = init.reshape(new_shards, nb, self.W).copy()
            new_packed = packed.reshape(new_shards, nb, self.W).copy()
            routed = chain.migrate(new_snap, new_init, new_packed)
            # the re-routed in-flight buffers, applied over the remapped
            # baseline, reproduce the pre-migration state of every key
            got_block = apply_route_buffer(routed, new_snap, shard,
                                           new_init[shard])
            np.testing.assert_array_equal(got_block, new_packed[shard])
            new_packed = self._evolve(rng, new_packed, post, chain)
            chain.wipe(shard)
            got = chain.restore_shard(shard)
            np.testing.assert_array_equal(got, new_packed[shard])

    @settings(max_examples=10, deadline=None)
    @given(combiner=st.sampled_from(["add", "min", "max", "replace"]),
           n_entries=st.integers(0, 4), seed=st.integers(0, 1 << 16))
    def test_migrate_route_buffers_all_combiners(self, combiner, n_entries,
                                                 seed):
        """The migration primitive itself, over every combiner: routing
        chronologically-ordered global-key buffers under a new snapshot
        must equal the per-key reference combine."""
        rng = np.random.default_rng(seed)
        new_snap = PartitionSnapshot(n_keys=64, num_shards=8)
        entries = []
        for _ in range(n_entries):
            k = rng.choice(64, size=int(rng.integers(1, 20)),
                           replace=False).astype(np.int32)
            p = rng.normal(size=(len(k), 1)).astype(np.float32)
            entries.append((k, p))
        routed = migrate_route_buffers(new_snap, entries, 1,
                                       combiner=combiner)
        ref = {}
        for k, p in entries:
            for key, val in zip(k.tolist(), p[:, 0].tolist()):
                if key not in ref:
                    ref[key] = val
                elif combiner == "add":
                    ref[key] = ref[key] + np.float32(val)
                elif combiner == "min":
                    ref[key] = min(ref[key], val)
                elif combiner == "max":
                    ref[key] = max(ref[key], val)
                else:
                    ref[key] = val                      # replace: last wins
        keys = np.asarray(routed.keys)
        payload = np.asarray(routed.payload[:, 0])
        live = keys != int(PAD_KEY)
        got = dict(zip(keys[live].tolist(), payload[live].tolist()))
        assert set(got) == set(ref)
        for key in ref:
            np.testing.assert_allclose(got[key], ref[key], rtol=1e-6)
        # every live key sits in its owner's segment
        seg = new_snap.block_size
        for slot in live.nonzero()[0]:
            assert int(new_snap.owner_of(
                jnp.asarray(keys[slot]))) == slot // seg


# ---------------------------------------------------------------------------
# Engine-level recovery: bit-identity under injected faults.
# ---------------------------------------------------------------------------

class TestResilientEngine:
    @pytest.mark.parametrize("route", ["sort", "scatter"])
    @pytest.mark.parametrize("name", ["pr", "sssp"])
    def test_failure_midfixpoint_bit_identical(self, graph, name, route,
                                               tmp_path):
        """The acceptance scenario: ladder_tiers=4, both route strategies,
        one shard lost at ~50% progress — incremental recovery must land
        bit-identical to the failure-free run AND beat restart on work."""
        _, _, snap, g = graph
        algo, state0, live0 = setup_algo(name, snap, g)
        ex = make_executor(snap, route_strategy=route)
        ref = ex.run(algo, state0, live0, g, 80)
        half = max(int(ref.stats.iterations) // 2, 1)
        work = {}
        for strategy in ("incremental", "restart"):
            rr = ex.run_resilient(
                algo, state0, live0, g, 80,
                ckpt_root=str(tmp_path / f"{name}-{route}-{strategy}"),
                fault_plan=FaultPlan(fail_at=half, failed_shard=1,
                                     strategy=strategy))
            assert rr.metrics["converged"]
            assert states_equal(ref.state, rr.result.state), strategy
            work[strategy] = rr.metrics["total_work_units"]
        assert work["incremental"] < work["restart"]
        assert work["incremental"] > 0

    @pytest.mark.parametrize("name", ["pr", "sssp", "maxprop"])
    def test_failure_all_combiners(self, graph, name, tmp_path):
        """add / min / max combining algorithms all recover exactly."""
        _, _, snap, g = graph
        algo, state0, live0 = setup_algo(name, snap, g)
        ex = make_executor(snap, route_strategy="auto")
        ref = ex.run(algo, state0, live0, g, 80)
        half = max(int(ref.stats.iterations) // 2, 1)
        rr = ex.run_resilient(
            algo, state0, live0, g, 80, ckpt_root=str(tmp_path / name),
            fault_plan=FaultPlan(fail_at=half, failed_shard=2))
        assert rr.metrics["converged"]
        assert states_equal(ref.state, rr.result.state)

    def test_nofail_matches_run_including_stats(self, graph, tmp_path):
        _, _, snap, g = graph
        algo, state0, live0 = setup_algo("pr", snap, g)
        ex = make_executor(snap, route_strategy="auto")
        ref = ex.run(algo, state0, live0, g, 80)
        rr = ex.run_resilient(algo, state0, live0, g, 80,
                              ckpt_root=str(tmp_path / "nf"))
        assert states_equal(ref.state, rr.result.state)
        assert int(rr.result.stats.iterations) == int(ref.stats.iterations)
        for field in ("delta_counts", "used_dense", "rehash_bytes", "tiers",
                      "routes"):
            np.testing.assert_array_equal(
                np.asarray(getattr(ref.stats, field)),
                np.asarray(getattr(rr.result.stats, field)), err_msg=field)
        # ladder + route dispatch really exercised under the driver
        iters = int(ref.stats.iterations)
        tiers = np.asarray(rr.result.stats.tiers)[:iters]
        assert tiers.min() >= 0 and tiers[-1] < tiers[0]

    def test_rescale_midfixpoint_and_fail_after(self, graph, tmp_path):
        """Elastic: fresh snapshot at ~50%, state + in-flight buffers
        migrated; a shard that exists ONLY under the new snapshot then
        dies and must restore from the migrated chain."""
        indptr, indices, snap, g = graph
        algo, state0, live0 = setup_algo("sssp", snap, g)
        ex = make_executor(snap, route_strategy="auto")

        def remake(new_snap):
            return (make_executor(new_snap, route_strategy="auto"),
                    sssp.make_algorithm(new_snap,
                                        src_capacity=new_snap.block_size,
                                        edge_capacity=8192),
                    shard_csr(indptr, indices, new_snap.num_shards))

        ref = ex.run(algo, state0, live0, g, 80)
        iters = int(ref.stats.iterations)
        ref_flat = np.asarray(unshard_dense_state(
            snap, jnp.stack(ref.state, -1)))
        for plan, tag in (
                (FaultPlan(rescale_at=iters // 2, new_num_shards=8),
                 "rescale"),
                (FaultPlan(rescale_at=max(iters // 2 - 1, 1),
                           new_num_shards=8, fail_at=iters // 2 + 1,
                           failed_shard=6), "rescale+fail")):
            rr = ex.run_resilient(algo, state0, live0, g, 80,
                                  ckpt_root=str(tmp_path / tag),
                                  fault_plan=plan, remake=remake)
            assert rr.metrics["converged"], tag
            assert rr.metrics["final_num_shards"] == 8, tag
            got = np.asarray(unshard_dense_state(
                snap.resnapshot(8), jnp.stack(rr.result.state, -1)))
            np.testing.assert_array_equal(ref_flat, got, err_msg=tag)

    def test_straggler_speculation_verified_against_replica(self, graph,
                                                            tmp_path):
        _, _, snap, g = graph
        algo, state0, live0 = setup_algo("sssp", snap, g)
        ex = make_executor(snap)
        rr = ex.run_resilient(
            algo, state0, live0, g, 80, ckpt_root=str(tmp_path),
            policy=SpeculationPolicy(threshold=2.0, min_history=1),
            latency_model=lambda stratum: [1.0, 1.0, 6.0, 1.0])
        assert rr.metrics["converged"]
        specs = rr.metrics["speculations"]
        assert specs and all(d["shard"] == 2 for d in specs)
        assert rr.metrics["speculation_saved_time"] > 0
        verified = rr.metrics["speculation_verified"]
        assert verified and all(v["ok"] for v in verified)

    def test_restart_needs_no_replication(self, graph, tmp_path):
        _, _, snap, g = graph
        algo, state0, live0 = setup_algo("sssp", snap, g)
        ex = make_executor(snap)
        rr = ex.run_resilient(
            algo, state0, live0, g, 80, ckpt_root=str(tmp_path),
            fault_plan=FaultPlan(fail_at=2, failed_shard=1,
                                 strategy="restart"),
            policy=SpeculationPolicy(threshold=2.0, min_history=1),
            latency_model=lambda stratum: [1.0, 1.0, 6.0, 1.0])
        assert rr.metrics["bytes_replicated"] == 0
        assert rr.metrics["converged"]
        # no replica chain -> nothing to speculate against: the driver
        # must not credit speculations or saved barrier time
        assert rr.metrics["speculations"] == []
        assert rr.metrics["speculation_saved_time"] == 0.0

    @settings(max_examples=5, deadline=None)
    @given(shard=st.integers(0, S - 1),
           first=st.integers(1, 3), gap=st.integers(1, 3))
    def test_repeated_same_shard_failure_across_strata(self, graph, shard,
                                                       first, gap):
        """Property: the SAME shard dying at two different strata (its
        replacement node dies too) recovers exactly both times — the
        paper's forward-progress guarantee under repeated failures."""
        _, _, snap, g = graph
        algo, state0, live0 = setup_algo("sssp", snap, g)
        ex = make_executor(snap, route_strategy="auto")
        ref = ex.run(algo, state0, live0, g, 80)
        schedule = FaultSchedule(events=(
            FaultEvent(kind="fail", at=first, shard=shard),
            FaultEvent(kind="fail", at=first + gap, shard=shard),
        ))
        with tempfile.TemporaryDirectory() as td:
            rr = ex.run_resilient(algo, state0, live0, g, 80,
                                  ckpt_root=td, fault_plan=schedule)
        assert rr.metrics["converged"]
        assert rr.metrics["recoveries"] == 2
        assert states_equal(ref.state, rr.result.state), \
            f"shard={shard} strata=({first},{first + gap})"

    @settings(max_examples=5, deadline=None)
    @given(at=st.integers(1, 4), new_shards=st.sampled_from([2, 8]),
           shard=st.integers(0, 1))
    def test_failure_during_elastic_rescale(self, graph, at, new_shards,
                                            shard):
        """Property: a failure injected DURING the rescale's migration
        (during='rescale' — fires under the NEW snapshot, against the
        barely-migrated chain) still lands bit-identical."""
        indptr, indices, snap, g = graph
        algo, state0, live0 = setup_algo("sssp", snap, g)
        ex = make_executor(snap, route_strategy="auto")

        def remake(new_snap):
            return (make_executor(new_snap, route_strategy="auto"),
                    sssp.make_algorithm(new_snap,
                                        src_capacity=new_snap.block_size,
                                        edge_capacity=8192),
                    shard_csr(indptr, indices, new_snap.num_shards))

        ref = ex.run(algo, state0, live0, g, 80)
        ref_flat = np.asarray(unshard_dense_state(
            snap, jnp.stack(ref.state, -1)))
        schedule = FaultSchedule(events=(
            FaultEvent(kind="rescale", at=at, new_num_shards=new_shards),
            FaultEvent(kind="fail", at=at, shard=shard % new_shards,
                       during="rescale"),
        ))
        with tempfile.TemporaryDirectory() as td:
            rr = ex.run_resilient(algo, state0, live0, g, 80,
                                  ckpt_root=td, fault_plan=schedule,
                                  remake=remake)
        assert rr.metrics["converged"]
        got = np.asarray(unshard_dense_state(
            snap.resnapshot(rr.metrics["final_num_shards"]),
            jnp.stack(rr.result.state, -1)))
        np.testing.assert_array_equal(
            ref_flat, got,
            err_msg=f"at={at} new_shards={new_shards} shard={shard}")


# ---------------------------------------------------------------------------
# Real-SPMD backend (subprocess: needs 8 virtual devices).
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_resilient_shard_map_bit_identical():
    """Failure mid-fixpoint on the shard_map backend: the stratum-sliced
    shard_map dispatch + replica restore must reproduce the fused
    shard_map run exactly."""
    from subproc import run_sub
    out = run_sub("""
import tempfile
import jax, jax.numpy as jnp
from repro.data.graphs import make_powerlaw_graph, shard_csr
from repro.core.partition import PartitionSnapshot
from repro.core.engine import ShardedExecutor
from repro.launch.mesh import flat_mesh
from repro.algorithms import pagerank, sssp
from repro.runtime import FaultPlan
n, S = 512, 8
indptr, indices = make_powerlaw_graph(n, avg_degree=8.0, seed=0)
snap = PartitionSnapshot(n_keys=n, num_shards=S)
g = shard_csr(indptr, indices, S)
ex = ShardedExecutor(snapshot=snap, seg_capacity=8192, edge_capacity=8192,
                     src_capacity=snap.block_size, backend='shard_map',
                     axis_name='shards', mesh=flat_mesh(S, 'shards'),
                     ladder_tiers=4)
for tag, mod, state0, live0 in (
        ('sp', sssp, sssp.initial_state(snap, 0), 1),
        ('pr', pagerank, pagerank.initial_state(snap), snap.padded_keys)):
    algo = mod.make_algorithm(snap, src_capacity=snap.block_size,
                              edge_capacity=8192)
    ref = ex.run(algo, state0, live0, g, 80)
    half = max(int(ref.stats.iterations) // 2, 1)
    with tempfile.TemporaryDirectory() as td:
        rr = ex.run_resilient(algo, state0, live0, g, 80, ckpt_root=td,
                              fault_plan=FaultPlan(fail_at=half,
                                                   failed_shard=3))
    assert rr.metrics['converged'], tag
    assert bool(jnp.all(jnp.stack([jnp.all(a == b) for a, b in
                                   zip(ref.state, rr.result.state)]))), tag
print('RESILIENT_SPMD_OK')
""")
    assert "RESILIENT_SPMD_OK" in out


# ---------------------------------------------------------------------------
# Standing queries survive executor failure mid-repair.
# ---------------------------------------------------------------------------

class TestResilientViews:
    def _mk(self, tmp_path, name, **params):
        from repro.incremental import ViewManager
        indptr, indices = make_powerlaw_graph(256, avg_degree=6.0, seed=3)
        mgr = ViewManager()
        view = mgr.create_graph_view(name, "pagerank", indptr, indices,
                                     256, num_shards=4, threshold=1e-4,
                                     **params)
        return mgr, view

    def test_view_survives_executor_failure_midrepair(self, tmp_path):
        from repro.incremental import EdgeInsert
        mgr_a, va = self._mk(tmp_path / "a", "va",
                             resilient_root=str(tmp_path / "chain_a"))
        mgr_b, vb = self._mk(tmp_path / "b", "vb",
                             resilient_root=str(tmp_path / "chain_b"))
        muts = [EdgeInsert(3, 9), EdgeInsert(70, 140), EdgeInsert(10, 201)]
        va.apply(*muts)
        vb.apply(*muts)
        va.fault_plan = FaultPlan(fail_at=1, failed_shard=1)
        ra = va.refresh(force="repair")
        rb = vb.refresh(force="repair")
        assert ra.mode == rb.mode == "repair"
        assert va.last_recovery is not None
        assert any(e["event"] == "failure"
                   for e in va.last_recovery["events"])
        np.testing.assert_array_equal(va.query(), vb.query())

    def test_batch_journaled_before_fixpoint(self, tmp_path):
        """Crash mid-repair: the sealed batch is already durable, so
        restore() replays it through the decided path."""
        from repro.incremental import EdgeInsert, ViewManager
        indptr, indices = make_powerlaw_graph(256, avg_degree=6.0, seed=3)
        root = str(tmp_path / "journal")
        mgr = ViewManager(journal_root=root)
        view = mgr.create_graph_view("pv", "pagerank", indptr, indices,
                                     256, num_shards=4, threshold=1e-4)
        mgr.mutate("pv", EdgeInsert(5, 9))
        mgr.refresh("pv")
        baseline = mgr.query("pv")

        class Boom(RuntimeError):
            pass

        # Second batch: the journal write (on_sealed) must land BEFORE the
        # repair fixpoint — simulate the executor dying inside resume.
        mgr.mutate("pv", EdgeInsert(80, 160))
        orig_resume = view.rule.resume
        view.rule.resume = lambda *a, **k: (_ for _ in ()).throw(Boom())
        with pytest.raises(Boom):
            mgr.refresh("pv")
        view.rule.resume = orig_resume

        restored = ViewManager.restore(root)
        got = restored.query("pv")
        # the restored view INCLUDES the batch whose repair crashed
        assert got.shape == baseline.shape
        twin = ViewManager()
        tv = twin.create_graph_view("tv", "pagerank", indptr, indices,
                                    256, num_shards=4, threshold=1e-4)
        tv.apply(EdgeInsert(5, 9))
        tv.refresh()
        tv.apply(EdgeInsert(80, 160))
        tv.refresh(force="repair")
        np.testing.assert_array_equal(got, tv.query())
