"""Chaos recovery overhead: multi-event fault schedules vs failure-free.

Extends Fig 12 from single-fault to the chaos regime: the pinned
acceptance schedule (3 faults — plain, correlated replica loss, and
failure-during-recovery) plus seeded random multi-event schedules run
through ``ShardedExecutor.run_resilient``, emitting total work and wall
overhead relative to the failure-free resilient run, replica/baseline
byte costs, retry/quarantine counters, and a bit-identity check of every
recovered state.  A final view-level drill measures what graceful
degradation costs: the degraded refresh (budget exhausted — serve stale)
and the cold catch-up that restores freshness.
"""
import shutil
import tempfile
import time

import numpy as np

import jax.numpy as jnp

from benchmarks.common import emit
from repro.algorithms import sssp
from repro.core.engine import ShardedExecutor
from repro.core.partition import PartitionSnapshot
from repro.data.graphs import load_dataset, make_powerlaw_graph
from repro.runtime import (ChaosConfig, FaultEvent, FaultSchedule,
                           RetryBudget, generate_schedule)
from repro.runtime.chaos import acceptance_schedule


def _identical(ref, res) -> bool:
    return bool(jnp.all(jnp.stack(
        [jnp.all(a == b) for a, b in zip(ref.state, res.result.state)])))


def main(quick: bool = False):
    dataset = "dbpedia-small" if quick else "dbpedia"
    S = 4 if quick else 8
    n, g = load_dataset(dataset, num_shards=S)
    snap = PartitionSnapshot(n_keys=n, num_shards=S)
    cap = max(65536, 4 * n)
    algo = sssp.make_algorithm(snap, src_capacity=snap.block_size,
                               edge_capacity=cap)
    ex = ShardedExecutor(snapshot=snap, seg_capacity=cap,
                         edge_capacity=cap, src_capacity=snap.block_size,
                         ladder_tiers=4, route_strategy="auto")
    state0 = sssp.initial_state(snap, 0)
    ref = ex.run(algo, state0, 1, g, 80)
    iters = int(ref.stats.iterations)

    tmp = tempfile.mkdtemp(prefix="bench_chaos_")
    try:
        _run_cases(ex, algo, state0, g, ref, iters, tmp, quick, dataset, S)
    finally:
        shutil.rmtree(tmp, ignore_errors=True)
    _degradation_drill(quick)


def _run_schedule(ex, algo, state0, g, schedule, root):
    t0 = time.perf_counter()
    res = ex.run_resilient(algo, state0, 1, g, 80, ckpt_root=root,
                           fault_plan=schedule)
    return res, time.perf_counter() - t0


def _run_cases(ex, algo, state0, g, ref, iters, tmp, quick, dataset, S):
    base, base_wall = _run_schedule(ex, algo, state0, g, None,
                                    f"{tmp}/nofail")
    base_work = base.metrics["total_work_units"]
    emit("chaos_nofail", base_work, "work_units",
         strata=iters, dataset=dataset, shards=S)
    emit("chaos_nofail_wall", base_wall, "s",
         repl_MB=round(base.metrics["bytes_replicated"] / 1e6, 2))

    # The ISSUE acceptance scenario, pinned: >= 3 faults including one
    # correlated replica loss and one failure striking mid-recovery.
    sched = acceptance_schedule(num_shards=S)
    res, wall = _run_schedule(ex, algo, state0, g, sched,
                              f"{tmp}/acceptance")
    work = res.metrics["total_work_units"]
    ok = _identical(ref, res)
    emit("chaos_acceptance", work, "work_units",
         faults=sched.fail_count,
         recoveries=res.metrics["recoveries"],
         restarts=res.metrics["restarts"],
         overhead_pct=round(100 * (work - base_work) / base_work, 1),
         repl_MB=round(res.metrics["bytes_replicated"] / 1e6, 2),
         io_retries=res.metrics["io_retries"],
         quarantined=res.metrics["checkpoints_quarantined"],
         bit_identical=int(ok))
    emit("chaos_acceptance_wall", wall, "s",
         overhead_pct=round(100 * (wall - base_wall) / base_wall, 1))
    assert ok, "acceptance schedule diverged from the failure-free run"

    # Seeded random schedules: repeated failures, correlated losses,
    # failures mid-recovery, transient stragglers (no rescale here — the
    # re-trace a rescale forces would dominate the wall numbers; rescale
    # chaos is covered by tests and the chaos CLI).
    seeds = (0, 7) if quick else (0, 3, 7, 11, 19)
    for seed in seeds:
        sched = generate_schedule(ChaosConfig(
            seed=seed, num_shards=S, n_events=3,
            max_stratum=max(iters - 1, 2), p_rescale=0.0,
            p_correlated=0.3, p_during_recovery=0.4, p_straggle=0.2))
        res, wall = _run_schedule(ex, algo, state0, g, sched,
                                  f"{tmp}/seed{seed}")
        work = res.metrics["total_work_units"]
        ok = _identical(ref, res)
        emit(f"chaos_seed{seed}", work, "work_units",
             events=len(sched.events), faults=sched.fail_count,
             recoveries=res.metrics["recoveries"],
             restarts=res.metrics["restarts"],
             overhead_pct=round(100 * (work - base_work) / base_work, 1),
             bit_identical=int(ok))
        emit(f"chaos_seed{seed}_wall", wall, "s")
        assert ok, f"chaos seed {seed} diverged from the failure-free run"


def _degradation_drill(quick: bool):
    """What graceful degradation costs at the view layer: the degraded
    refresh (recovery budget exhausted — serve the stale snapshot with
    metadata) and the cold catch-up refresh that restores freshness."""
    from repro.incremental.mutations import EdgeInsert
    from repro.incremental.view import ViewManager

    n = 1024 if quick else 4096
    indptr, indices = make_powerlaw_graph(n, avg_degree=8.0, seed=1)
    mgr = ViewManager()
    view = mgr.create_graph_view("chaos", "sssp", indptr, indices, n,
                                 num_shards=4, source=0)
    view.fault_plan = FaultSchedule(events=(
        FaultEvent(kind="fail", at=0, shard=1),))
    view.retry_budget = RetryBudget(max_recoveries=0)
    mgr.mutate("chaos", EdgeInsert(0, n // 2))
    report = mgr.refresh("chaos")["chaos"]
    ans = mgr.query("chaos", detail=True)
    assert report.mode == "degraded" and ans.degraded
    emit("chaos_degraded_refresh_wall", report.wall_s, "s",
         reason=ans.reason, stale_batches=ans.stale_batches,
         served_version=ans.version, latest_version=ans.latest_version)

    view.retry_budget = None
    catchup = mgr.refresh("chaos")["chaos"]
    fresh = mgr.query("chaos", detail=True)
    assert catchup.mode == "cold" and not fresh.degraded
    emit("chaos_catchup_wall", catchup.wall_s, "s",
         mode=catchup.mode, version=fresh.version)
    # The degraded answer really was the last converged snapshot, and
    # catch-up really changed it (the inserted edge shortens distances).
    assert not np.array_equal(ans.value, fresh.value)


if __name__ == "__main__":
    main()
