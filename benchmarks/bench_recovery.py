"""Fig 12 — recovery: incremental vs restart, failure at stratum k.

Total work units (incl. redone work) to convergence of SSSP with one node
failure injected at varying strata — the paper's y-axis, with incremental
recovery roughly halving the overhead and guaranteeing forward progress."""
import shutil
import tempfile

import numpy as np

import jax.numpy as jnp

from benchmarks.common import emit
from repro.algorithms import sssp
from repro.core.engine import ShardedExecutor
from repro.core.partition import PartitionSnapshot
from repro.data.graphs import load_dataset
from repro.runtime import CheckpointManager, StratumRunner, run_with_failure


def main():
    n, g = load_dataset("dbpedia-small", num_shards=4)
    S = 4
    snap = PartitionSnapshot(n_keys=n, num_shards=S)
    algo = sssp.make_algorithm(snap, src_capacity=snap.block_size,
                               edge_capacity=max(65536, 4 * n))
    ex = ShardedExecutor(snapshot=snap, seg_capacity=max(65536, 4 * n),
                         edge_capacity=max(65536, 4 * n),
                         src_capacity=snap.block_size)
    sfn = ex.make_stratum_fn(algo, g, "delta")

    def make_runner():
        return StratumRunner(stratum_fn=sfn,
                             state=sssp.initial_state(snap, 0), live=1)

    def mutable_of(state):
        st = sssp.SPState(*state)
        return np.stack([np.asarray(st.dist), np.asarray(st.sent)], -1)

    def restore(state, shard, node):
        st = sssp.SPState(*state)
        return sssp.SPState(
            dist=st.dist.at[node].set(jnp.asarray(shard[:, 0])),
            sent=st.sent.at[node].set(jnp.asarray(shard[:, 1])))

    # no-failure baseline
    tmp = tempfile.mkdtemp()
    base = run_with_failure(
        make_runner, CheckpointManager(f"{tmp}/b", num_nodes=S),
        mutable_of, restore, fail_at=None, failed_node=0,
        strategy="restart")
    emit("fig12_recovery_nofail", base["total_work_units"], "work_units")

    for fail_at in (1, 3, 5, 7):
        for strategy in ("incremental", "restart"):
            ck = CheckpointManager(f"{tmp}/{strategy}{fail_at}",
                                   num_nodes=S, replication=3)
            res = run_with_failure(make_runner, ck, mutable_of, restore,
                                   fail_at=fail_at, failed_node=1,
                                   strategy=strategy)
            emit(f"fig12_recovery_fail{fail_at}_{strategy}",
                 res["total_work_units"], "work_units",
                 overhead_pct=round(100 * (res["total_work_units"]
                                           - base["total_work_units"])
                                    / base["total_work_units"], 1),
                 repl_MB=round(res["bytes_replicated"] / 1e6, 2))
    shutil.rmtree(tmp)


if __name__ == "__main__":
    main()
