"""Fig 12 — recovery: incremental vs restart, failure at varying strata.

Runs SSSP through the production engine's fault-tolerant driver
(``ShardedExecutor.run_resilient`` — density ladder + adaptive route
dispatch intact) with one shard lost at 25/50/75% of the failure-free
stratum count.  Emits the paper's y-axis — total work units including
redone strata — for both recovery strategies, the replica-chain byte
overhead, wall clocks, and a bit-identity check of every recovered final
state against the failure-free ``ShardedExecutor.run``.
"""
import shutil
import tempfile
import time

import jax.numpy as jnp

from benchmarks.common import emit
from repro.algorithms import sssp
from repro.core.engine import ShardedExecutor
from repro.core.partition import PartitionSnapshot
from repro.data.graphs import load_dataset
from repro.runtime import FaultPlan, SpeculationPolicy


def main(quick: bool = False):
    dataset = "dbpedia-small" if quick else "dbpedia"
    S = 4 if quick else 8
    n, g = load_dataset(dataset, num_shards=S)
    snap = PartitionSnapshot(n_keys=n, num_shards=S)
    cap = max(65536, 4 * n)
    algo = sssp.make_algorithm(snap, src_capacity=snap.block_size,
                               edge_capacity=cap)
    ex = ShardedExecutor(snapshot=snap, seg_capacity=cap,
                         edge_capacity=cap, src_capacity=snap.block_size,
                         ladder_tiers=4, route_strategy="auto")
    state0 = sssp.initial_state(snap, 0)

    ref = ex.run(algo, state0, 1, g, 80)
    iters = int(ref.stats.iterations)
    tmp = tempfile.mkdtemp()
    try:
        _run_cases(ex, algo, state0, g, ref, iters, tmp, quick, dataset, S)
    finally:
        shutil.rmtree(tmp, ignore_errors=True)


def _run_cases(ex, algo, state0, g, ref, iters, tmp, quick, dataset, S):
    t0 = time.perf_counter()
    base = ex.run_resilient(algo, state0, 1, g, 80,
                            ckpt_root=f"{tmp}/nofail")
    nofail_wall = time.perf_counter() - t0
    base_work = base.metrics["total_work_units"]
    emit("recovery_nofail", base_work, "work_units",
         strata=iters, dataset=dataset, shards=S)
    emit("recovery_nofail_wall", nofail_wall, "s",
         repl_MB=round(base.metrics["bytes_replicated"] / 1e6, 2))

    fractions = (0.5,) if quick else (0.25, 0.5, 0.75)
    for frac in fractions:
        fail_at = max(int(iters * frac), 1)
        for strategy in ("incremental", "restart"):
            t0 = time.perf_counter()
            res = ex.run_resilient(
                algo, state0, 1, g, 80,
                ckpt_root=f"{tmp}/{strategy}{fail_at}",
                fault_plan=FaultPlan(fail_at=fail_at, failed_shard=1,
                                     strategy=strategy))
            wall = time.perf_counter() - t0
            work = res.metrics["total_work_units"]
            identical = bool(jnp.all(jnp.stack(
                [jnp.all(a == b)
                 for a, b in zip(ref.state, res.result.state)])))
            emit(f"recovery_fail{int(frac * 100)}_{strategy}", work,
                 "work_units",
                 overhead_pct=round(100 * (work - base_work) / base_work,
                                    1),
                 repl_MB=round(res.metrics["bytes_replicated"] / 1e6, 2),
                 bit_identical=int(identical))
            emit(f"recovery_fail{int(frac * 100)}_{strategy}_wall", wall,
                 "s")
            assert identical, (
                f"{strategy} recovery diverged from the failure-free run")

    # Straggler speculation fed by MEASURED per-stratum latencies (no
    # synthetic latency_model): the driver's own wall clocks drive the
    # policy — the observability loop closed end to end.
    spec = ex.run_resilient(
        algo, state0, 1, g, 80, ckpt_root=f"{tmp}/spec",
        policy=SpeculationPolicy(threshold=3.0, min_history=2))
    emit("recovery_speculation_measured",
         len(spec.metrics["speculations"]), "count",
         latency_source=spec.metrics["latency_source"],
         verified=sum(1 for v in spec.metrics["speculation_verified"]
                      if v["ok"]),
         strata=spec.metrics["strata_executed"],
         median_stratum_ms=round(1e3 * sorted(
             spec.metrics["stratum_wall_s"])[
             len(spec.metrics["stratum_wall_s"]) // 2], 3))
    assert spec.metrics["latency_source"] == "measured"


if __name__ == "__main__":
    main()
