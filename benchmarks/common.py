"""Shared benchmark helpers: timing, CSV emission."""
import time

import jax


def timeit(fn, *args, warmup: int = 2, reps: int = 5):
    """Median wall time of fn(*args) with block_until_ready."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    times = []
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2]


def emit(name: str, value, unit: str = "s", **extra):
    kv = ",".join(f"{k}={v}" for k, v in extra.items())
    print(f"{name},{value:.6g},{unit}" + ("," + kv if kv else ""),
          flush=True)
