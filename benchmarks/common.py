"""Shared benchmark helpers: timing, CSV emission, artifact records.

Every ``emit()`` call both prints the legacy CSV line and appends a
structured record to an in-process collector; ``benchmarks/run.py`` drains
the collector after each suite into a machine-readable
``BENCH_<suite>.json`` artifact so the perf trajectory is tracked across
PRs (and uploaded by CI).
"""
import time

import jax

_RECORDS: list[dict] = []


def tier_histogram(stats) -> str:
    """Per-rung stratum counts, e.g. '[12;4;0;43]' (index 0 = smallest
    ladder rung; dense strata excluded)."""
    import numpy as np
    iters = int(stats.iterations)
    tiers = np.asarray(stats.tiers)[:iters]
    if iters == 0 or tiers.max(initial=-1) < 0:
        return "[]"
    counts = np.bincount(tiers[tiers >= 0], minlength=int(tiers.max()) + 1)
    return "[" + ";".join(str(int(c)) for c in counts) + "]"


def route_histogram(stats) -> str:
    """Per-strategy stratum counts '[sort;scatter]' (dense strata and
    runs predating the routes field excluded)."""
    import numpy as np
    if getattr(stats, "routes", None) is None:
        return "[]"
    iters = int(stats.iterations)
    routes = np.asarray(stats.routes)[:iters]
    if iters == 0 or routes.max(initial=-1) < 0:
        return "[]"
    counts = np.bincount(routes[routes >= 0], minlength=2)
    return "[" + ";".join(str(int(c)) for c in counts[:2]) + "]"


def timeit(fn, *args, warmup: int = 2, reps: int = 5):
    """Median wall time of fn(*args) with block_until_ready."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    times = []
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2]


def timeit_split(fn, *args, reps: int = 5):
    """Split timing: ``(compile_s, steady_s)``.

    The FIRST call is timed separately — it includes tracing + XLA
    compilation, the number a "why is my benchmark slow" report usually
    conflates with steady-state throughput.  ``steady_s`` is the median
    of ``reps`` subsequent calls (all cache hits).  Use this instead of
    :func:`timeit` wherever the compile cost is itself a datapoint.
    """
    t0 = time.perf_counter()
    jax.block_until_ready(fn(*args))
    compile_s = time.perf_counter() - t0
    times = []
    for _ in range(max(reps, 1)):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        times.append(time.perf_counter() - t0)
    times.sort()
    return compile_s, times[len(times) // 2]


def environment_metadata() -> dict:
    """Backend/device/version stamp embedded in every BENCH_*.json —
    cross-machine artifact diffs are meaningless without it."""
    import platform
    devs = jax.devices()
    return {
        "jax_version": jax.__version__,
        "backend": jax.default_backend(),
        "device_count": jax.device_count(),
        "device_kind": devs[0].device_kind if devs else "none",
        "platform": platform.platform(),
        "python": platform.python_version(),
    }


def metrics_snapshot() -> dict:
    """Snapshot of the default metrics registry (empty dict when nothing
    was recorded) — drained into the artifact next to the records."""
    from repro.obs import default_registry
    return default_registry().snapshot()


def emit(name: str, value, unit: str = "s", **extra):
    kv = ",".join(f"{k}={v}" for k, v in extra.items())
    print(f"{name},{value:.6g},{unit}" + ("," + kv if kv else ""),
          flush=True)
    _RECORDS.append({"name": name, "value": float(value), "unit": unit,
                     **extra})


def reset_records() -> None:
    """Start a fresh record set (one per benchmark suite)."""
    _RECORDS.clear()


def drain_records() -> list[dict]:
    """Return and clear the records emitted since the last reset."""
    out = list(_RECORDS)
    _RECORDS.clear()
    return out
