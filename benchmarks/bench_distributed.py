"""Real multi-process launch-path costs vs the simulated driver.

Measures what the distributed control plane adds on top of the
single-process resilient driver: cluster bring-up wall, the failure-free
``DistributedResilientDriver`` overhead (broadcast + ack collection every
barrier), and the headline acceptance number — the recovery work a REAL
mid-run SIGKILL costs relative to the simulated equivalent (a
``FaultEvent`` injected at the stratum where the lease table actually
detected the kill).  Both faulted runs must stay bit-identical to the
failure-free reference; the real/sim work-overhead ratio must stay
within 2x.  Detection latency is emitted informationally (ms — it is
lease-TTL-bound by design, not a regression signal).  Full mode also
times the real ``jax.distributed`` 4-process bring-up selftest.
"""
import shutil
import tempfile
import time

import numpy as np

import jax.numpy as jnp

from benchmarks.common import emit
from repro.algorithms import sssp
from repro.core.engine import ShardedExecutor
from repro.core.partition import PartitionSnapshot, unshard_dense_state
from repro.data.graphs import load_dataset
from repro.launch.distributed import (Cluster, DistributedResilientDriver,
                                      selftest)
from repro.runtime import FaultEvent, FaultSchedule
from repro.runtime.health import HealthConfig


def _flat(snap, state) -> np.ndarray:
    return np.asarray(unshard_dense_state(snap, jnp.stack(state, -1)))


def main(quick: bool = False):
    dataset = "dbpedia-small" if quick else "dbpedia"
    S = 4
    n, g = load_dataset(dataset, num_shards=S)
    snap = PartitionSnapshot(n_keys=n, num_shards=S)
    cap = max(65536, 4 * n)
    algo = sssp.make_algorithm(snap, src_capacity=snap.block_size,
                               edge_capacity=cap)
    ex = ShardedExecutor(snapshot=snap, seg_capacity=cap,
                         edge_capacity=cap, src_capacity=snap.block_size,
                         ladder_tiers=4, route_strategy="auto")
    state0 = sssp.initial_state(snap, 0)
    ref = ex.run(algo, state0, 1, g, 80)
    ref_flat = _flat(snap, ref.state)
    iters = int(ref.stats.iterations)

    tmp = tempfile.mkdtemp(prefix="bench_dist_")
    # A short lease keeps the real-kill detection (and hence the replay
    # window gap vs the simulated equivalent) tight for the bench.
    cfg = HealthConfig(lease_ttl=0.8, straggle_after=0.25,
                       heartbeat_interval=0.05, ack_timeout=0.5)
    cluster = None
    try:
        # Simulated failure-free baseline: the same resilient machinery
        # with no workers and no faults.
        t0 = time.perf_counter()
        base = ex.run_resilient(algo, state0, 1, g, 80,
                                ckpt_root=f"{tmp}/nofail")
        base_wall = time.perf_counter() - t0
        base_work = base.metrics["total_work_units"]
        emit("dist_sim_nofail_wall", base_wall, "s",
             work_units=base_work, strata=iters, dataset=dataset, shards=S)

        # Untimed warmup of the recovery path (restore + replay + reseed
        # trace/compile once here) so the real-vs-sim recovery ratio
        # below compares steady-state walls, not who paid warmup.
        ex.run_resilient(algo, state0, 1, g, 80, ckpt_root=f"{tmp}/warm",
                         fault_plan=FaultSchedule(events=(
                             FaultEvent(kind="fail", at=2, shard=1),)))

        # Control-plane bring-up: spawn + first heartbeat + assignment.
        t0 = time.perf_counter()
        cluster = Cluster(f"{tmp}/cluster", S, num_shards=S, config=cfg,
                          detect="lease")
        cluster.start()
        emit("dist_bringup_wall", time.perf_counter() - t0, "s",
             workers=S, jax="off", detect="lease")

        # Failure-free distributed run: every barrier broadcasts the
        # stratum and collects real acks; the delta is pure control-plane
        # overhead.
        t0 = time.perf_counter()
        drv = DistributedResilientDriver(
            ex, algo, state0, 1, g, 80, ckpt_root=f"{tmp}/ff",
            cluster=cluster)
        ff = drv.run()
        ff_wall = time.perf_counter() - t0
        ff_ok = np.array_equal(ref_flat, _flat(snap, ff.result.state))
        emit("dist_failfree_wall", ff_wall, "s",
             work_units=ff.metrics["total_work_units"],
             overhead_pct=round(100 * (ff_wall - base_wall) / base_wall, 1),
             acks=ff.metrics["acks_collected"],
             ack_timeouts=ff.metrics["ack_timeouts"],
             bit_identical=int(ff_ok))
        assert ff_ok, "failure-free distributed run diverged"
        assert ff.metrics["acks_collected"] > 0

        # Real mid-run SIGKILL: delivered at the first barrier at
        # stratum >= 2, detected by the lease table when the heartbeat
        # age crosses the TTL.
        killed = []

        def hook(d):
            if not killed and d.stratum >= 2:
                killed.append(d.stratum)
                cluster.kill(1)

        t0 = time.perf_counter()
        drv = DistributedResilientDriver(
            ex, algo, state0, 1, g, 80, ckpt_root=f"{tmp}/real",
            cluster=cluster, chaos_hook=hook)
        real = drv.run()
        real_wall = time.perf_counter() - t0
        real_ok = np.array_equal(ref_flat, _flat(snap, real.result.state))
        real_work = real.metrics["total_work_units"]
        dets = real.metrics["worker_detections"]
        assert killed, "fixpoint converged before the kill stratum"
        assert dets, "the SIGKILL was never detected (run too short?)"
        det = dets[0]
        emit("dist_real_kill_wall", real_wall, "s",
             work_units=real_work,
             recoveries=real.metrics["recoveries"],
             restarts=real.metrics["restarts"],
             recovery_wall_s=real.metrics["recovery_wall_s"],
             bit_identical=int(real_ok))
        emit("dist_detection_latency", det["detection_s"] * 1000.0, "ms",
             detect="lease", ttl_s=cfg.lease_ttl,
             kill_stratum=killed[0], detect_stratum=det["stratum"])
        assert real_ok, "real-kill run diverged from the reference"

        # Simulated equivalent: inject the SAME failure (shards, stratum)
        # the lease table actually detected, through the plain driver.
        dead = next(e for e in real.metrics["events"]
                    if e["event"] == "worker_dead")
        sched = FaultSchedule(events=tuple(
            FaultEvent(kind="fail", at=det["stratum"], shard=s)
            for s in dead["shards"]))
        t0 = time.perf_counter()
        sim = ex.run_resilient(algo, state0, 1, g, 80,
                               ckpt_root=f"{tmp}/sim", fault_plan=sched)
        sim_wall = time.perf_counter() - t0
        sim_work = sim.metrics["total_work_units"]
        sim_ok = np.array_equal(ref_flat, _flat(snap, sim.result.state))
        emit("dist_sim_kill_wall", sim_wall, "s",
             work_units=sim_work,
             recoveries=sim.metrics["recoveries"],
             recovery_wall_s=sim.metrics["recovery_wall_s"],
             bit_identical=int(sim_ok))
        assert sim_ok, "simulated-kill run diverged from the reference"
        # Forward work is identical by construction (recovery is replay,
        # not recomputation) — Fig 12's ~0%-overhead claim, now under a
        # real kill.
        assert real_work == sim_work == base_work, (
            real_work, sim_work, base_work)

        # The acceptance ratio: wall spent inside _recover (restore +
        # replay + reseed) for the real kill vs the simulated equivalent.
        # Same code path, same schedule — ~1.0; must stay within 2x.
        real_oh = real.metrics["recovery_wall_s"]
        sim_oh = max(sim.metrics["recovery_wall_s"], 1e-9)
        ratio = real_oh / sim_oh
        emit("dist_real_vs_sim_overhead", ratio, "x",
             real_recovery_wall_s=real_oh, sim_recovery_wall_s=round(
                 sim_oh, 6))
        assert real_oh > 0 and sim_oh > 0
        assert ratio <= 2.0, (
            f"real-kill recovery wall {real_oh:.3f}s exceeds 2x the "
            f"simulated equivalent {sim_oh:.3f}s")

        if not quick:
            # Real jax.distributed bring-up: 4 processes x 2 devices,
            # coordination service + one cross-process collective.
            t0 = time.perf_counter()
            rep = selftest(num_workers=4, devices_per_worker=2)
            emit("dist_jax_bringup_wall", time.perf_counter() - t0, "s",
                 processes=rep["num_workers"],
                 global_devices=rep["global_devices"],
                 collective_ok=int(rep["collective_ok"]))
    finally:
        if cluster is not None:
            cluster.shutdown()
        shutil.rmtree(tmp, ignore_errors=True)


if __name__ == "__main__":
    main()
