"""Benchmark harness: one module per paper table/figure.

  PYTHONPATH=src python -m benchmarks.run [--only fig5,...]

Emits ``name,value,unit[,k=v...]`` CSV lines per data point.
"""
import argparse
import sys
import time
import traceback

from benchmarks import (bench_agg, bench_bandwidth, bench_compression,
                        bench_incremental, bench_kmeans, bench_pagerank,
                        bench_recovery, bench_scalability, bench_sssp)

SUITES = [
    ("fig4_agg", bench_agg),
    ("fig5_kmeans", bench_kmeans),
    ("fig6_pagerank", bench_pagerank),      # also fig2, fig8
    ("fig7_sssp", bench_sssp),              # also fig9
    ("fig10_scalability", bench_scalability),
    ("fig11_bandwidth", bench_bandwidth),
    ("fig12_recovery", bench_recovery),
    ("compression", bench_compression),     # beyond-paper
    ("incremental", bench_incremental),     # beyond-paper: view maintenance
]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="")
    args = ap.parse_args()
    sel = [s for s in args.only.split(",") if s]
    failed = []
    for name, mod in SUITES:
        if sel and not any(k in name for k in sel):
            continue
        print(f"# === {name} ===", flush=True)
        t0 = time.time()
        try:
            mod.main()
        except Exception:  # noqa: BLE001 — keep the harness going
            traceback.print_exc()
            failed.append(name)
        print(f"# {name} done in {time.time() - t0:.1f}s", flush=True)
    if failed:
        print(f"# FAILED: {failed}", file=sys.stderr)
        sys.exit(1)
    print("# all suites complete", flush=True)


if __name__ == "__main__":
    main()
