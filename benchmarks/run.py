"""Benchmark harness: one module per paper table/figure.

  PYTHONPATH=src python -m benchmarks.run [--only fig5,...] [--quick]
      [--artifact-dir bench_artifacts]

Emits ``name,value,unit[,k=v...]`` CSV lines per data point AND one
machine-readable ``BENCH_<suite>.json`` artifact per suite (records +
config + wall clock) under ``--artifact-dir`` so the perf trajectory is
tracked across PRs.  ``--quick`` runs each suite's reduced configuration
(small datasets, fewer reps) — the CI smoke mode.
"""
import argparse
import inspect
import json
import os
import sys
import time
import traceback

from benchmarks import (bench_agg, bench_bandwidth, bench_chaos,
                        bench_compression, bench_distributed,
                        bench_frontend, bench_incremental, bench_kmeans,
                        bench_pagerank, bench_recovery, bench_rehash,
                        bench_scalability, bench_sssp, common)

SUITES = [
    ("fig4_agg", bench_agg),
    ("fig5_kmeans", bench_kmeans),
    ("fig6_pagerank", bench_pagerank),      # also fig2, fig8
    ("fig7_sssp", bench_sssp),              # also fig9
    ("fig10_scalability", bench_scalability),
    ("fig11_bandwidth", bench_bandwidth),
    ("recovery", bench_recovery),               # fig12, resilient engine
    ("chaos", bench_chaos),                 # beyond-paper: chaos schedules
    ("distributed", bench_distributed),     # beyond-paper: real launch path
    ("compression", bench_compression),     # beyond-paper
    ("incremental", bench_incremental),     # beyond-paper: view maintenance
    ("rehash", bench_rehash),               # beyond-paper: route strategies
    ("frontend", bench_frontend),           # rules-vs-handwritten overhead
]


def write_artifact(artifact_dir: str, suite: str, records: list,
                   wall_s: float, quick: bool, failed: bool) -> str:
    os.makedirs(artifact_dir, exist_ok=True)
    path = os.path.join(artifact_dir, f"BENCH_{suite}.json")
    payload = {
        "suite": suite,
        "quick": quick,
        "failed": failed,
        "wall_s": round(wall_s, 3),
        "config": common.environment_metadata(),
        "metrics": common.metrics_snapshot(),
        "records": records,
    }
    with open(path, "w") as f:
        json.dump(payload, f, indent=2, sort_keys=True)
        f.write("\n")
    return path


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="")
    ap.add_argument("--quick", action="store_true",
                    help="reduced configs (CI smoke mode)")
    ap.add_argument("--artifact-dir", default="bench_artifacts",
                    help="where BENCH_<suite>.json artifacts are written")
    args = ap.parse_args()
    sel = [s for s in args.only.split(",") if s]
    failed = []
    for name, mod in SUITES:
        if sel and not any(k in name for k in sel):
            continue
        print(f"# === {name} ===", flush=True)
        common.reset_records()
        from repro.obs import reset_default_registry
        reset_default_registry()    # per-suite metrics in the artifact
        kwargs = {}
        if args.quick and "quick" in inspect.signature(mod.main).parameters:
            kwargs["quick"] = True
        t0 = time.time()
        suite_failed = False
        try:
            mod.main(**kwargs)
        except Exception:  # noqa: BLE001 — keep the harness going
            traceback.print_exc()
            failed.append(name)
            suite_failed = True
        wall = time.time() - t0
        path = write_artifact(args.artifact_dir, name,
                              common.drain_records(), wall, args.quick,
                              suite_failed)
        print(f"# {name} done in {wall:.1f}s -> {path}", flush=True)
    if failed:
        print(f"# FAILED: {failed}", file=sys.stderr)
        sys.exit(1)
    print("# all suites complete", flush=True)


if __name__ == "__main__":
    main()
