"""Figs 7/9 — shortest path: delta (frontier Δᵢ) vs nodelta."""
import numpy as np

import jax

from benchmarks.common import emit, timeit
from repro.algorithms import sssp
from repro.core.partition import PartitionSnapshot
from repro.data.graphs import load_dataset


def run(dataset: str, shards: int = 8, max_iters: int = 80):
    n, g = load_dataset(dataset, num_shards=shards)
    snap = PartitionSnapshot(n_keys=n, num_shards=shards)
    cap = dict(edge_capacity=max(65536, 4 * n),
               src_capacity=snap.block_size)
    for mode in ("delta", "nodelta"):
        f = jax.jit(lambda g, mode=mode: sssp.run(
            g, snap, source=0, mode=mode, max_iters=max_iters,
            **cap)[0])
        dt = timeit(f, g, warmup=1, reps=3)
        _, res = sssp.run(g, snap, source=0, mode=mode,
                          max_iters=max_iters, **cap)
        emit(f"fig7_sssp_{dataset}_{mode}", dt, "s",
             iters=int(res.stats.iterations),
             rehash_MB=float(np.sum(res.stats.rehash_bytes)) / 1e6)


def main():
    run("dbpedia-small")
    run("twitter-small")


if __name__ == "__main__":
    main()
