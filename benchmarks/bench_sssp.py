"""Figs 7/9 — shortest path: delta (frontier Δᵢ) vs nodelta.

The delta mode also runs with the capacity ladder (beyond-paper): the BFS
frontier starts tiny, explodes, then shrinks — exactly the profile the
per-stratum rung dispatch exploits.
"""
import numpy as np

import jax

from benchmarks.common import emit, tier_histogram, timeit
from repro.algorithms import sssp
from repro.core.partition import PartitionSnapshot
from repro.data.graphs import load_dataset


def run(dataset: str, shards: int = 8, max_iters: int = 80,
        ladder_tiers: int = 4):
    n, g = load_dataset(dataset, num_shards=shards)
    snap = PartitionSnapshot(n_keys=n, num_shards=shards)
    cap = dict(edge_capacity=max(65536, 4 * n),
               src_capacity=snap.block_size)
    variants = [("delta", 1), ("delta_ladder", ladder_tiers), ("nodelta", 1)]
    for variant, tiers in variants:
        mode = "nodelta" if variant == "nodelta" else "delta"
        f = jax.jit(lambda g, mode=mode, tiers=tiers: sssp.run(
            g, snap, source=0, mode=mode, max_iters=max_iters,
            ladder_tiers=tiers, **cap)[0])
        dt = timeit(f, g, warmup=1, reps=3)
        _, res = sssp.run(g, snap, source=0, mode=mode,
                          max_iters=max_iters, ladder_tiers=tiers, **cap)
        emit(f"fig7_sssp_{dataset}_{variant}", dt, "s",
             iters=int(res.stats.iterations), shards=shards,
             rehash_MB=float(np.sum(res.stats.rehash_bytes)) / 1e6,
             ladder_tiers=tiers,
             tier_histogram=tier_histogram(res.stats))


def main(quick: bool = False):
    run("dbpedia-small", shards=4 if quick else 8)
    if not quick:
        run("twitter-small")


if __name__ == "__main__":
    main()
