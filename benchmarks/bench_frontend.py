"""Frontend overhead: compiled rule programs vs handwritten algorithms.

The declarative pipeline (rules → plan IR → optimizer → lowering) must be
a compile-time luxury only: once lowered, the DeltaAlgorithm runs through
the identical executor machinery, so steady-state wall clock should match
the handwritten ``algorithms/`` versions within noise.  This suite measures
both sides for PageRank / SSSP / CC (plus rules-only reachability, which
has no handwritten counterpart) and emits the relative overhead; the
budget is ≤5%, enforced here for datapoints large enough to be meaningful
on shared runners and gated in CI via compare_artifacts.
"""
import numpy as np

import jax

from benchmarks.common import emit, timeit_split
from repro import frontend as F
from repro.algorithms import connected_components as cc
from repro.algorithms import pagerank, sssp
from repro.core.partition import PartitionSnapshot
from repro.data.graphs import load_dataset

#: steady-state overhead budget for compiled-vs-handwritten (fraction).
OVERHEAD_BUDGET = 0.05
#: handwritten steady times below this are runner noise, not a gate.
GATE_FLOOR_S = 0.05


def _cases(max_iters):
    return [
        ("pagerank", F.pagerank_program(),
         lambda g, snap, cap: pagerank.run(g, snap, max_iters=max_iters,
                                           **cap)),
        ("sssp", F.sssp_program(),
         lambda g, snap, cap: sssp.run(g, snap, source=0,
                                       max_iters=max_iters, **cap)),
        ("cc", F.cc_program(),
         lambda g, snap, cap: cc.run(g, snap, max_iters=max_iters, **cap)),
    ]


def run(dataset: str, shards: int = 8, max_iters: int = 60):
    n, g = load_dataset(dataset, num_shards=shards)
    snap = PartitionSnapshot(n_keys=n, num_shards=shards)
    cap = dict(edge_capacity=max(65536, 4 * n), src_capacity=snap.block_size)
    over_budget = []
    for name, prog, handwritten in _cases(max_iters):
        compiled = F.compile_program(prog)
        f_hand = jax.jit(lambda g, r=handwritten:
                         r(g, snap, cap)[1].stats.delta_counts)
        f_comp = jax.jit(lambda g, c=compiled:
                         c.run(g, snap, max_iters=max_iters,
                               **cap)[1].stats.delta_counts)
        hand_compile, hand_s = timeit_split(f_hand, g, reps=3)
        comp_compile, comp_s = timeit_split(f_comp, g, reps=3)
        overhead = comp_s / hand_s - 1.0
        emit(f"frontend_{name}_handwritten", hand_s, "s",
             shards=shards, iters=max_iters,
             compile_s=round(hand_compile, 4))
        emit(f"frontend_{name}_compiled", comp_s, "s",
             shards=shards, iters=max_iters,
             compile_s=round(comp_compile, 4))
        emit(f"frontend_{name}_overhead", 100.0 * overhead, "pct",
             budget_pct=100.0 * OVERHEAD_BUDGET,
             gated=hand_s >= GATE_FLOOR_S)
        if hand_s >= GATE_FLOOR_S and overhead > OVERHEAD_BUDGET:
            over_budget.append((name, overhead))
    # Rules-only reachability: no handwritten twin, absolute time only.
    compiled = F.compile_program(F.reachability_program())
    f_reach = jax.jit(lambda g, c=compiled:
                      c.run(g, snap, max_iters=max_iters,
                            **cap)[1].stats.delta_counts)
    reach_compile, reach_s = timeit_split(f_reach, g, reps=3)
    emit("frontend_reachability_compiled", reach_s, "s", shards=shards,
         iters=max_iters, compile_s=round(reach_compile, 4))
    if over_budget:
        raise AssertionError(
            "compiled programs exceeded the steady-state overhead budget "
            f"({100 * OVERHEAD_BUDGET:.0f}%): "
            + ", ".join(f"{n}: {100 * o:.1f}%" for n, o in over_budget))


def main(quick: bool = False):
    run("dbpedia-small", shards=4 if quick else 8)
    if not quick:
        run("twitter-small")


if __name__ == "__main__":
    main()
