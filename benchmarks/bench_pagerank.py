"""Figs 2/6/8 — PageRank: delta vs nodelta, totals + per-iteration Δᵢ.

Reports wall time (CPU, relative), per-stratum Δᵢ counts (Fig 2), dense
fallbacks, and exact rehash bytes — the quantities behind the paper's
10× (DBPedia) / 3–7× (Twitter) claims.  The delta mode is additionally run
with the capacity ladder enabled (beyond-paper): per-stratum dispatch to
the smallest capacity rung that fits the predicted |Δᵢ|, so tail-stratum
cost tracks |Δᵢ| instead of the static worst-case capacity.  Ladder and
fixed-capacity runs are bit-identical (tested); only wall clock moves.
"""
import numpy as np

import jax

from benchmarks.common import (emit, route_histogram, tier_histogram,
                               timeit_split)
from repro.algorithms import pagerank
from repro.core.partition import PartitionSnapshot
from repro.data.graphs import load_dataset


def run(dataset: str, shards: int = 8, threshold: float = 1e-3,
        max_iters: int = 60, ladder_tiers: int = 4):
    n, g = load_dataset(dataset, num_shards=shards)
    snap = PartitionSnapshot(n_keys=n, num_shards=shards)
    cap = dict(edge_capacity=max(65536, 4 * n), src_capacity=snap.block_size)
    # (variant, ladder tiers, rehash strategy): the _auto variant is the
    # sort-free scatter rehash under the per-rung cost model — same delta
    # counts and rehash bytes as the sort path (recorded into the
    # artifact as counts_bit_identical; the hard assertion lives in
    # tests/test_rehash_strategies.py), only the physical grouping
    # changes.
    variants = [("delta", 1, "sort"), ("delta_ladder", ladder_tiers, "sort"),
                ("delta_ladder_auto", ladder_tiers, "auto"),
                ("nodelta", 1, "sort")]
    baseline_stats = None
    for variant, tiers, route in variants:
        mode = "nodelta" if variant == "nodelta" else "delta"
        f = jax.jit(lambda g, mode=mode, tiers=tiers, route=route:
                    pagerank.run(
                        g, snap, mode=mode, threshold=threshold,
                        max_iters=max_iters, ladder_tiers=tiers,
                        route_strategy=route, **cap)[1].stats.delta_counts)
        compile_s, dt = timeit_split(f, g, reps=3)
        _, res = pagerank.run(g, snap, mode=mode, threshold=threshold,
                              max_iters=max_iters, ladder_tiers=tiers,
                              route_strategy=route, **cap)
        iters = int(res.stats.iterations)
        extra = {}
        if variant == "delta_ladder":
            baseline_stats = res.stats
        elif variant == "delta_ladder_auto" and baseline_stats is not None:
            extra["counts_bit_identical"] = bool(
                np.array_equal(np.asarray(res.stats.delta_counts),
                               np.asarray(baseline_stats.delta_counts))
                and np.array_equal(np.asarray(res.stats.rehash_bytes),
                                   np.asarray(baseline_stats.rehash_bytes)))
        emit(f"fig6_pagerank_{dataset}_{variant}", dt, "s",
             iters=iters, shards=shards,
             compile_s=round(compile_s, 4),
             rehash_MB=float(np.sum(res.stats.rehash_bytes)) / 1e6,
             dense_fallbacks=int(np.sum(res.stats.used_dense)),
             ladder_tiers=tiers,
             tier_histogram=tier_histogram(res.stats),
             route_histogram=route_histogram(res.stats), **extra)
        if variant == "delta":
            counts = np.asarray(res.stats.delta_counts)[:iters]
            head = ",".join(str(int(c)) for c in counts[:12])
            emit(f"fig2_delta_counts_{dataset}", float(counts[-1]),
                 "deltas_final", first12=f"[{head}]")


def main(quick: bool = False):
    run("dbpedia-small", shards=4 if quick else 8)
    if not quick:
        run("dbpedia")


if __name__ == "__main__":
    main()
