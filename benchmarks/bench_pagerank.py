"""Figs 2/6/8 — PageRank: delta vs nodelta, totals + per-iteration Δᵢ.

Reports wall time (CPU, relative), per-stratum Δᵢ counts (Fig 2), dense
fallbacks, and exact rehash bytes — the quantities behind the paper's
10× (DBPedia) / 3–7× (Twitter) claims.
"""
import numpy as np

import jax

from benchmarks.common import emit, timeit
from repro.algorithms import pagerank
from repro.core.partition import PartitionSnapshot
from repro.data.graphs import load_dataset


def run(dataset: str, shards: int = 8, threshold: float = 1e-3,
        max_iters: int = 60):
    n, g = load_dataset(dataset, num_shards=shards)
    snap = PartitionSnapshot(n_keys=n, num_shards=shards)
    cap = dict(edge_capacity=max(65536, 4 * n), src_capacity=snap.block_size)
    for mode in ("delta", "nodelta"):
        f = jax.jit(lambda g, mode=mode: pagerank.run(
            g, snap, mode=mode, threshold=threshold, max_iters=max_iters,
            **cap)[1].stats.delta_counts)
        dt = timeit(f, g, warmup=1, reps=3)
        _, res = pagerank.run(g, snap, mode=mode, threshold=threshold,
                              max_iters=max_iters, **cap)
        iters = int(res.stats.iterations)
        emit(f"fig6_pagerank_{dataset}_{mode}", dt, "s",
             iters=iters,
             rehash_MB=float(np.sum(res.stats.rehash_bytes)) / 1e6,
             dense_fallbacks=int(np.sum(res.stats.used_dense)))
        if mode == "delta":
            counts = np.asarray(res.stats.delta_counts)[:iters]
            head = ",".join(str(int(c)) for c in counts[:12])
            emit(f"fig2_delta_counts_{dataset}", float(counts[-1]),
                 "deltas_final", first12=f"[{head}]")


def main():
    run("dbpedia-small")
    run("dbpedia")


if __name__ == "__main__":
    main()
