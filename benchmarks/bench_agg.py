"""Fig 4 — simple OLAP aggregation: built-in vs UDA vs wrapped execution.

  SELECT sum(tax), count(*) FROM lineitem WHERE linenumber > 1

``builtin``: the engine's built-in sum/count aggregators + comparison
predicate.  ``udf``: the same query with the selection and both aggregates
expressed as user-defined code (the REX claim: UDC within ~10% of
builtins because tracing erases call overhead).  ``wrap``: UDFs that
round-trip values through a string format (modeling the Hadoop-wrapper
impedance the paper measures).
"""
import numpy as np

import jax
import jax.numpy as jnp

from benchmarks.common import emit, timeit
from repro.core.operators import Table, apply_function, group_by, select

N = 1_000_000


def make_lineitem(n=N, seed=0):
    rng = np.random.default_rng(seed)
    return Table.from_columns(
        linenumber=jnp.asarray(rng.integers(1, 8, n).astype(np.int32)),
        tax=jnp.asarray(rng.random(n).astype(np.float32) * 0.1),
        group=jnp.zeros(n, jnp.int32))


def q_builtin(t):
    t = select(t, lambda t: t.columns["linenumber"] > 1)
    return group_by(t, "group", {"s": ("sum", "tax"),
                                 "c": ("count", "tax")}, 1)


def q_udf(t):
    t = apply_function(t, lambda ln: {"keep": ln > 1}, ("linenumber",))
    t = select(t, lambda t: t.columns["keep"])
    t = apply_function(t, lambda tax: {"tax2": tax * 1.0}, ("tax",))
    return group_by(t, "group", {"s": ("sum", "tax2"),
                                 "c": ("count", "tax2")}, 1)


def q_wrap(t):
    # Hadoop-wrapper model: values bounce through an int encoding
    # (text-format round trip) before aggregation.
    def fmt(tax):
        enc = (tax * 1e6).astype(jnp.int32)      # "format to text"
        return {"tax2": enc.astype(jnp.float32) / 1e6}  # "parse back"
    t = apply_function(t, lambda ln: {"keep": ln > 1}, ("linenumber",))
    t = select(t, lambda t: t.columns["keep"])
    t = apply_function(t, fmt, ("tax",))
    return group_by(t, "group", {"s": ("sum", "tax2"),
                                 "c": ("count", "tax2")}, 1)


def main():
    t = make_lineitem()
    ref = None
    for name, q in (("builtin", q_builtin), ("udf", q_udf),
                    ("wrap", q_wrap)):
        f = jax.jit(lambda t, q=q: (q(t).columns["s"], q(t).columns["c"]))
        dt = timeit(f, t)
        s, c = f(t)
        if ref is None:
            ref = float(s[0])
        assert abs(float(s[0]) - ref) < 1e-2 * abs(ref)
        emit(f"fig4_agg_{name}", dt * 1e6 / 1.0, "us_per_query",
             sum=float(s[0]), count=float(c[0]))


if __name__ == "__main__":
    main()
