"""Fig 5 — k-means: delta vs nodelta over input sizes (the paper's ~100×
Hadoop gap comes from per-iteration re-shuffle; here the delta/nodelta
gap shows up in switch-set work and shuffle-byte accounting)."""
import numpy as np

import jax

from benchmarks.common import emit, timeit
from repro.algorithms import kmeans
from repro.data.points import make_geo_points, sample_initial_centroids


def run(n_points: int, k: int = 32, shards: int = 8):
    pts = make_geo_points(n_points, n_true_clusters=k, seed=0)
    init = sample_initial_centroids(pts, k, seed=1)
    pts_sh = pts.reshape(shards, n_points // shards, 2)
    for mode in ("delta", "nodelta"):
        f = jax.jit(lambda p, i, mode=mode: kmeans.run(
            p, i, mode=mode, max_iters=60)[0])
        dt = timeit(f, pts_sh, init, warmup=1, reps=3)
        _, res = kmeans.run(pts_sh, init, mode=mode, max_iters=60)
        emit(f"fig5_kmeans_n{n_points}_{mode}", dt, "s",
             iters=int(res.stats.iterations),
             shuffle_MB=float(np.sum(res.stats.rehash_bytes)) / 1e6)


def main():
    for n in (4096, 32768, 131072):
        run(n)


if __name__ == "__main__":
    main()
