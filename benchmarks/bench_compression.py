"""Beyond-paper: REX-delta gradient compression — wire bytes vs loss.

The Δᵢ-set idea applied to distributed SGD (DESIGN.md §6): error-feedback
top-k sparsification vs int8 vs uncompressed, trained on the same data —
reporting wire bytes per step and final loss (quality preserved)."""
import jax

from benchmarks.common import emit
from repro.configs import get_arch
from repro.data.tokens import TokenPipeline
from repro.train import TrainConfig, init_train_state, make_train_step
from repro.train.optimizer import AdamWConfig


def main():
    cfg = get_arch("olmo-1b").reduced()
    pipe = TokenPipeline(vocab=cfg.vocab, seq_len=64, global_batch=8)
    for comp in ("none", "int8", "delta"):
        tcfg = TrainConfig(
            compression=comp, topk_frac=0.05,
            adamw=AdamWConfig(lr=5e-3, warmup_steps=5, total_steps=60))
        state = init_train_state(cfg, tcfg, jax.random.PRNGKey(0))
        step = jax.jit(make_train_step(cfg, tcfg))
        loss = wire = None
        for i in range(60):
            state, m = step(state, pipe.batch_at(i))
        loss, wire = float(m["loss"]), float(m["wire_bytes"])
        if comp == "none":   # uncompressed wire = f32 grads
            import jax as _jax
            wire = 4.0 * sum(x.size for x in
                             _jax.tree.leaves(state.params))
        emit(f"compression_{comp}", wire / 1e6, "MB_per_step",
             final_loss=round(loss, 4))


if __name__ == "__main__":
    main()
