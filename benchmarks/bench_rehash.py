"""Rehash strategy microbenchmark: sort- vs scatter-based combine-route.

Times one local rehash (``combine_route`` vs ``combine_route_scatter``)
across buffer capacities C, shard counts S, and every composable combiner
— the per-stratum hot path the ladder rungs dispatch to.  The crossover
this sweep exposes (sort cost ~ C·log₂C vs scatter cost ~ C + slab cells)
is what calibrates ``ShardedExecutor.route_scatter_weight`` and the
"auto" per-rung strategy choice.  Also reports what "auto" picks at each
point, so the committed BENCH_rehash.json documents the dispatch.
"""
import numpy as np

import jax
import jax.numpy as jnp

from benchmarks.common import emit, timeit
from repro.core.delta import (ANN_ADJUST, DeltaBuffer, combine_route,
                              combine_route_scatter)
from repro.core.engine import ShardedExecutor
from repro.core.partition import PartitionSnapshot

N_KEYS = 65536           # dbpedia-shaped key space (slab size driver)
COMBINERS = ["add", "min", "max", "replace"]


def make_buffer(rng, capacity: int, fill: float = 0.75) -> DeltaBuffer:
    count = int(capacity * fill)
    keys = np.full(capacity, -1, np.int32)
    keys[:count] = rng.integers(0, N_KEYS, count)
    pay = rng.normal(size=(capacity, 1)).astype(np.float32)
    pay[count:] = 0
    return DeltaBuffer(
        keys=jnp.asarray(keys), payload=jnp.asarray(pay),
        ann=jnp.full(capacity, ANN_ADJUST, jnp.int8),
        count=jnp.asarray(count), overflowed=jnp.asarray(False))


def run(capacities, shard_counts, combiners, reps: int = 5):
    rng = np.random.default_rng(0)
    for S in shard_counts:
        snap = PartitionSnapshot(n_keys=N_KEYS, num_shards=S)
        ex = ShardedExecutor(snapshot=snap, seg_capacity=1,
                             edge_capacity=1, src_capacity=1,
                             route_strategy="auto")
        for C in capacities:
            db = make_buffer(rng, C)
            owners = snap.owner_of(db.keys)
            seg_cap = C  # segment budget == rung edge budget (engine's)
            for combiner in combiners:
                auto_pick = ex.pick_route_strategy(C, combiner)
                # Return the whole buffer so XLA cannot dead-code-eliminate
                # the payload merge.
                sort_fn = jax.jit(lambda db, o: combine_route(
                    db, o, S, seg_cap, combiner))
                scatter_fn = jax.jit(lambda db, o: combine_route_scatter(
                    db, o, S, seg_cap, combiner, snapshot=snap))
                t_sort = timeit(sort_fn, db, owners, warmup=2, reps=reps)
                t_scatter = timeit(scatter_fn, db, owners, warmup=2,
                                   reps=reps)
                for strat, t in (("sort", t_sort), ("scatter", t_scatter)):
                    emit(f"rehash_c{C}_s{S}_{combiner}_{strat}", t, "s",
                         C=C, S=S, n_keys=N_KEYS, combiner=combiner,
                         strategy=strat, auto_pick=auto_pick,
                         speedup_scatter=round(t_sort / t_scatter, 3))


def main(quick: bool = False):
    if quick:
        run([256, 4096], [4], ["add", "min"], reps=3)
    else:
        run([256, 1024, 4096, 16384, 65536], [4, 8], COMBINERS)


if __name__ == "__main__":
    main()
