"""Fig 11 — bandwidth: exact rehash bytes, delta vs dense, PR + SSSP.

The paper: REX delta 0.97 MB/s vs Hadoop 2.0 MB/s per node on PageRank;
larger gap for SSSP.  Here bytes are counted exactly by the engine."""
import numpy as np

from benchmarks.common import emit
from repro.algorithms import pagerank, sssp
from repro.core.partition import PartitionSnapshot
from repro.data.graphs import load_dataset


def main():
    n, g = load_dataset("dbpedia", num_shards=8)
    snap = PartitionSnapshot(n_keys=n, num_shards=8)
    cap = dict(edge_capacity=max(65536, 4 * n),
               src_capacity=snap.block_size)
    for name, algo, kw in (
            ("pagerank", pagerank, dict(threshold=1e-3, max_iters=40)),
            ("sssp", sssp, dict(source=0, max_iters=60))):
        per = {}
        for mode in ("delta", "nodelta"):
            _, res = algo.run(g, snap, mode=mode, **kw, **cap)
            per[mode] = float(np.sum(res.stats.rehash_bytes))
            emit(f"fig11_bandwidth_{name}_{mode}", per[mode] / 1e6, "MB",
                 iters=int(res.stats.iterations))
        emit(f"fig11_bandwidth_{name}_ratio",
             per["nodelta"] / max(per["delta"], 1), "x_dense_over_delta")


if __name__ == "__main__":
    main()
