"""Observability acceptance run: traced resilient PageRank with a
recovery event, exported as a Perfetto timeline + metrics snapshot.

  PYTHONPATH=src python -m benchmarks.export_trace \
      [--quick] [--out bench_fresh] [--check-overhead 5]

Runs dbpedia-small PageRank through ``ShardedExecutor.run_resilient``
with a tracer + metrics registry attached, one shard lost mid-fixpoint
(incremental recovery) and a ``SpeculationPolicy`` fed by MEASURED
per-stratum latencies (no synthetic model).  Writes:

  * ``TRACE_pagerank_resilient.json``   — Chrome-trace/Perfetto timeline
    (open in https://ui.perfetto.dev or chrome://tracing): per-stratum
    spans per shard row, driver row with stratum slices + replicate
    spans, instants for the failure and any speculation verdicts.
  * ``METRICS_pagerank_resilient.json`` — flat registry snapshot
    (engine.* / recovery.* counters, gauges, latency histograms) plus
    run metadata.

``--check-overhead PCT`` additionally times the SAME fused fixpoint
traced vs untraced (median of reps) and fails when tracing costs more
than PCT percent wall clock — the CI guard for "observability is free
when off, cheap when on".
"""
import argparse
import json
import os
import sys

from benchmarks.common import emit, timeit_split
from repro.algorithms import pagerank
from repro.core.engine import ShardedExecutor
from repro.core.partition import PartitionSnapshot
from repro.data.graphs import load_dataset
from repro.obs import (MetricsRegistry, Tracer, metrics_to_json,
                       write_chrome_trace)
from repro.runtime import FaultPlan, SpeculationPolicy


def _mk(snap, n, tracer=None):
    cap = max(65536, 4 * n)
    return ShardedExecutor(snapshot=snap, seg_capacity=cap,
                           edge_capacity=cap,
                           src_capacity=snap.block_size,
                           ladder_tiers=4, route_strategy="auto",
                           tracer=tracer)


def run_traced_resilient(out_dir: str, shards: int, ckpt_root: str):
    """The acceptance scenario; returns (trace_path, metrics_path)."""
    n, g = load_dataset("dbpedia-small", num_shards=shards)
    snap = PartitionSnapshot(n_keys=n, num_shards=shards)
    algo = pagerank.make_algorithm(snap, src_capacity=snap.block_size,
                                   edge_capacity=max(65536, 4 * n))
    state0 = pagerank.initial_state(snap)
    live0 = snap.padded_keys

    tracer = Tracer("pagerank_resilient", metrics=MetricsRegistry())
    ex = _mk(snap, n, tracer=tracer)
    ref = ex.run(algo, state0, live0, g, 80)       # also warms the cache
    iters = int(ref.stats.iterations)
    tracer.clear()                                 # keep only the run below

    rr = ex.run_resilient(
        algo, state0, live0, g, 80, ckpt_root=ckpt_root,
        fault_plan=FaultPlan(fail_at=max(iters // 2, 1), failed_shard=1),
        policy=SpeculationPolicy(threshold=3.0, min_history=2),
        metrics=tracer.metrics)
    assert rr.metrics["converged"]
    assert rr.metrics["latency_source"] == "measured"
    assert any(e["event"] == "failure" for e in rr.metrics["events"])

    os.makedirs(out_dir, exist_ok=True)
    trace_path = os.path.join(out_dir, "TRACE_pagerank_resilient.json")
    write_chrome_trace(tracer, trace_path)
    metrics_path = os.path.join(out_dir, "METRICS_pagerank_resilient.json")
    extra = {
        "run": "pagerank_resilient_dbpedia-small",
        "shards": shards,
        "strata_executed": rr.metrics["strata_executed"],
        "events": rr.metrics["events"],
        "latency_source": rr.metrics["latency_source"],
        "stratum_wall_s": [round(w, 6)
                           for w in rr.metrics["stratum_wall_s"]],
    }
    with open(metrics_path, "w") as f:
        json.dump(metrics_to_json(tracer.metrics, extra=extra), f,
                  indent=2, sort_keys=True)
        f.write("\n")

    emit("export_trace_events", len(tracer.events), "count",
         strata=rr.metrics["strata_executed"],
         recovery_events=len(rr.metrics["events"]))
    return trace_path, metrics_path


def check_overhead(shards: int, pct: float, reps: int = 5) -> float:
    """Traced vs untraced fused fixpoint (steady-state medians).  Returns
    the measured overhead percentage; raises SystemExit beyond ``pct``."""
    n, g = load_dataset("dbpedia-small", num_shards=shards)
    snap = PartitionSnapshot(n_keys=n, num_shards=shards)
    algo = pagerank.make_algorithm(snap, src_capacity=snap.block_size,
                                   edge_capacity=max(65536, 4 * n))
    state0 = pagerank.initial_state(snap)
    live0 = snap.padded_keys

    def bench(tracer):
        ex = _mk(snap, n, tracer=tracer)
        _, steady = timeit_split(
            lambda: ex.run(algo, state0, live0, g, 60).stats.iterations,
            reps=reps)
        return steady

    plain = bench(None)
    traced = bench(Tracer("overhead"))
    overhead = 100.0 * (traced - plain) / plain
    emit("export_trace_overhead", traced, "s", untraced=round(plain, 6),
         overhead_pct=round(overhead, 2), limit_pct=pct)
    if overhead > pct:
        print(f"# tracing overhead {overhead:.1f}% exceeds the "
              f"{pct:.1f}% budget", file=sys.stderr)
        raise SystemExit(1)
    return overhead


def main(quick: bool = False, out: str = "bench_fresh",
         check: float = None, ckpt_root: str = None):
    import shutil
    import tempfile
    shards = 4 if quick else 8
    tmp = ckpt_root or tempfile.mkdtemp()
    try:
        trace_path, metrics_path = run_traced_resilient(out, shards, tmp)
        print(f"# trace   -> {trace_path}")
        print(f"# metrics -> {metrics_path}")
    finally:
        if ckpt_root is None:
            shutil.rmtree(tmp, ignore_errors=True)
    if check is not None:
        check_overhead(shards, check)


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--out", default="bench_fresh")
    ap.add_argument("--check-overhead", type=float, default=None,
                    metavar="PCT",
                    help="fail if traced steady-state wall clock exceeds "
                         "the untraced one by more than PCT percent")
    args = ap.parse_args()
    main(quick=args.quick, out=args.out, check=args.check_overhead)
