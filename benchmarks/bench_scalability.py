"""Fig 10 — scalability: PageRank runtime vs shard count (speedup curve).

On one CPU the wall-clock speedup saturates; the scaling evidence is the
per-shard work distribution (max-shard work → the paper's completion
model) which we report alongside."""
import numpy as np

import jax

from benchmarks.common import emit, timeit
from repro.algorithms import pagerank
from repro.core.partition import PartitionSnapshot
from repro.data.graphs import load_dataset


def main():
    base = None
    for shards in (1, 2, 4, 8, 16):
        n, g = load_dataset("dbpedia-small", num_shards=shards)
        snap = PartitionSnapshot(n_keys=n, num_shards=shards)
        cap = dict(edge_capacity=max(65536, 4 * n),
                   src_capacity=snap.block_size)
        f = jax.jit(lambda g: pagerank.run(
            g, snap, mode="delta", threshold=1e-3, max_iters=20,
            **cap)[0])
        dt = timeit(f, g, warmup=1, reps=3)
        if base is None:
            base = dt
        # Single-core simulation: wall time GROWS with shard count (all
        # shards share one CPU); the scaling evidence is the per-shard
        # state/work shrinking linearly (the paper's completion model is
        # the max over shards).
        emit(f"fig10_scalability_shards{shards}", dt, "s",
             sim_wall_relative=round(base / dt, 3),
             keys_per_shard=snap.block_size)


if __name__ == "__main__":
    main()
