"""Beyond-paper — incremental view maintenance: warm repair vs cold rerun.

A standing query absorbs a stream of base-data mutation batches (mixed
edge inserts/deletes, ≤1% of edges per batch).  For every batch we time
the warm path (translate batch → seed deltas → resume fixpoint from the
converged state) against a cold from-scratch fixpoint on the SAME mutated
graph, and compare the bytes the rehash moved.  This is the REX delta
argument applied across queries instead of across strata: the paper's
systems (and Pregelix/HaLoop-style successors) re-run the whole recursive
job on input change; the view repairs it.

Emits per algorithm: median warm/cold wall clock, speedup, strata, and
rehash traffic.  Acceptance target: ≥2× on PageRank and SSSP.
"""
import numpy as np

from benchmarks.common import emit, timeit
from repro.data.graphs import DATASETS, make_powerlaw_graph
from repro.incremental import EdgeDelete, EdgeInsert, ViewManager


def mutation_stream(store, rng, frac: float):
    """One batch: frac·|E| mixed inserts (uniform) + deletes (existing)."""
    half = max(int(store.n_edges * frac / 2), 1)
    muts = [EdgeInsert(int(rng.integers(store.n)), int(rng.integers(store.n)))
            for _ in range(half)]
    src, dst = store.edges()
    for i in rng.choice(len(src), half, replace=False):
        muts.append(EdgeDelete(int(src[i]), int(dst[i])))
    return muts


def bench_view(dataset: str, algo: str, shards: int, batches: int,
               frac: float, seed: int = 0, tag: str = "", **params):
    n, avg, alpha = DATASETS[dataset]
    indptr, indices = make_powerlaw_graph(n, avg, alpha, seed=seed)
    mgr = ViewManager(fallback_threshold=2.0)   # measure the repair path
    view = mgr.create_graph_view("v", algo, indptr, indices, n,
                                 num_shards=shards, **params)
    rng = np.random.default_rng(seed)

    # Warm up both compiled paths (cold compiled at creation; one throwaway
    # batch compiles the resume path and the repair translation).
    mgr.mutate("v", *mutation_stream(view.store, rng, frac))
    mgr.refresh("v")

    warm_s, cold_s, warm_bytes, cold_bytes, warm_strata, cold_strata, \
        repaired = [], [], [], [], [], [], 0
    for _ in range(batches):
        mgr.mutate("v", *mutation_stream(view.store, rng, frac))
        report = mgr.refresh("v")["v"]
        warm_s.append(report.wall_s)
        warm_bytes.append(report.rehash_bytes)
        warm_strata.append(report.strata)
        repaired += report.mode == "repair"

        # Cold rerun on the same mutated graph (compiled, includes device
        # fixpoint only — the store rebuild is charged to the warm side).
        cold_s.append(timeit(lambda: view.rule.cold(view)[1]
                             .stats.delta_counts, warmup=0, reps=3))
        _, res = view.rule.cold(view)
        it = int(res.stats.iterations)
        cold_bytes.append(float(np.sum(
            np.asarray(res.stats.rehash_bytes)[:it])))
        cold_strata.append(it)

    med_w, med_c = float(np.median(warm_s)), float(np.median(cold_s))
    emit(f"incremental_{algo}_{dataset}{tag}", med_c / max(med_w, 1e-12), "x",
         warm_ms=round(med_w * 1e3, 3), cold_ms=round(med_c * 1e3, 3),
         warm_strata=float(np.median(warm_strata)),
         cold_strata=float(np.median(cold_strata)),
         warm_MB=round(float(np.mean(warm_bytes)) / 1e6, 4),
         cold_MB=round(float(np.mean(cold_bytes)) / 1e6, 4),
         repaired=f"{repaired}/{batches}",
         batch_frac=frac)
    return med_c / max(med_w, 1e-12)


def main(dataset: str = "dbpedia-small", shards: int = 4,
         batches: int = 8, frac: float = 0.01, quick: bool = False):
    if quick:
        batches = 3
    # Ladder off vs on (warm resumes are tail-stratum-dominated, so the
    # per-stratum rung dispatch is where the repair path gains).
    bench_view(dataset, "pagerank", shards, batches, frac,
               threshold=1e-4, max_iters=100, ladder_tiers=1,
               tag="_ladder_off")
    bench_view(dataset, "pagerank", shards, batches, frac,
               threshold=1e-4, max_iters=100, ladder_tiers=4,
               tag="_ladder_on")
    if quick:
        return
    bench_view(dataset, "sssp", shards, batches, frac,
               source=0, max_iters=100)
    bench_view(dataset, "connected_components", shards, batches, frac,
               max_iters=100)


if __name__ == "__main__":
    main()
