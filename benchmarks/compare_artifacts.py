"""Regression gate: diff fresh BENCH artifacts against committed ones.

  PYTHONPATH=src python -m benchmarks.compare_artifacts \
      [--baseline bench_artifacts] [--fresh bench_fresh] \
      [--threshold 0.25] [--only fig6,...]

Matches datapoints by (suite, record name) and fails (exit 1) when a
fresh wall-clock record regresses more than ``threshold`` relative to
the committed baseline.  Only seconds-unit records gate — counts,
byte totals, and histograms are informational — and only records whose
baseline is at least ``--min-seconds`` (sub-millisecond microbench
points swing far more than 25% on shared CI runners; they are reported
but never fail).  Suites are only compared when both sides ran the same
mode (quick vs full): CI runs ``--quick`` and the committed artifacts
are seeded in quick mode so the configurations line up.
Matched-but-faster datapoints and new/unmatched names never fail: the
gate is one-sided, catching "this PR made the rehash 2× slower" loudly
while tolerating noise below the threshold.

Exit codes: 0 = compared clean; 1 = regressions (or a fresh suite
failed); 2 = nothing fresh to compare; 3 = clean BUT one or more suites
were not actually gated — skipped for a quick/full mode mismatch, or
present in the baseline yet MISSING from the fresh run (a suite silently
dropped from the bench matrix must not read as a pass).  CI should treat
that as a misconfiguration, not a pass.
"""
from __future__ import annotations

import argparse
import glob
import json
import os
import sys


def load_artifacts(path: str) -> dict[str, dict]:
    out = {}
    for f in glob.glob(os.path.join(path, "BENCH_*.json")):
        with open(f) as fh:
            payload = json.load(fh)
        out[payload["suite"]] = payload
    return out


def compare(baseline: dict, fresh: dict, threshold: float,
            min_seconds: float) -> tuple[list[str], list[str]]:
    """-> (regressions, notes) for one suite."""
    regressions, notes = [], []
    base_by_name = {r["name"]: r for r in baseline.get("records", [])}
    for rec in fresh.get("records", []):
        name = rec["name"]
        base = base_by_name.get(name)
        if base is None:
            notes.append(f"  new datapoint (no baseline): {name}")
            continue
        if rec.get("unit") != "s" or base.get("unit") != "s":
            continue
        b, f = float(base["value"]), float(rec["value"])
        if b <= 0:
            continue
        ratio = f / b
        line = f"{name}: {b:.4g}s -> {f:.4g}s ({ratio:.2f}x)"
        if b < min_seconds:
            notes.append("  info (below gate floor) " + line)
        elif ratio > 1.0 + threshold:
            regressions.append("  REGRESSION " + line)
        else:
            notes.append("  ok " + line)
    return regressions, notes


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--baseline", default="bench_artifacts",
                    help="committed artifact dir (the reference)")
    ap.add_argument("--fresh", default="bench_fresh",
                    help="artifact dir of the run under test")
    ap.add_argument("--threshold", type=float, default=0.25,
                    help="max tolerated relative wall-clock regression")
    ap.add_argument("--min-seconds", type=float, default=0.05,
                    help="baselines below this never gate (runner noise)")
    ap.add_argument("--only", default="",
                    help="comma-separated suite substrings to compare")
    args = ap.parse_args()
    sel = [s for s in args.only.split(",") if s]
    base_suites = load_artifacts(args.baseline)
    fresh_suites = load_artifacts(args.fresh)
    if not fresh_suites:
        print(f"no BENCH_*.json artifacts under {args.fresh}",
              file=sys.stderr)
        return 2
    failed = False
    mode_skipped: list[str] = []
    for suite, fresh in sorted(fresh_suites.items()):
        if sel and not any(k in suite for k in sel):
            continue
        base = base_suites.get(suite)
        print(f"# === {suite} ===")
        if base is None:
            print("  no committed baseline — skipped")
            continue
        if bool(base.get("quick")) != bool(fresh.get("quick")):
            print(f"  mode mismatch (baseline quick={base.get('quick')}, "
                  f"fresh quick={fresh.get('quick')}) — skipped")
            mode_skipped.append(suite)
            continue
        if fresh.get("failed"):
            print("  fresh run FAILED — counted as regression")
            failed = True
            continue
        regressions, notes = compare(base, fresh, args.threshold,
                                     args.min_seconds)
        for line in notes:
            print(line)
        for line in regressions:
            print(line)
        if regressions:
            failed = True
    missing = sorted(
        suite for suite in base_suites
        if suite not in fresh_suites
        and (not sel or any(k in suite for k in sel)))
    if missing:
        # A baseline suite the fresh run never produced: the gate cannot
        # vouch for it.  Same failure class as a mode-mismatch skip.
        print("# WARNING: baseline suite(s) missing from the fresh run: "
              f"{', '.join(missing)} — these suites were NOT gated; run "
              "them or retire their committed baselines", file=sys.stderr)
    if mode_skipped:
        # Loud and unmissable: a skipped suite is an UNGATED suite.  The
        # usual cause is re-seeding committed baselines with a full run
        # while CI compares in --quick (or vice versa).
        print("# WARNING: mode mismatch skipped "
              f"{len(mode_skipped)} suite(s): {', '.join(mode_skipped)} "
              "— these suites were NOT gated; re-seed the baseline in "
              "the comparison mode", file=sys.stderr)
    if failed:
        print(f"# wall-clock regressions beyond {args.threshold:.0%} "
              "detected", file=sys.stderr)
        return 1
    if mode_skipped or missing:
        return 3
    print("# no wall-clock regressions beyond threshold")
    return 0


if __name__ == "__main__":
    sys.exit(main())
